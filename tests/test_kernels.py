"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret=True executes the Pallas kernel body on CPU)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

SHAPES = [(8, 128), (60, 300), (128, 512), (100, 1000), (7, 130), (256, 131)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_edpp_screen_kernel(shape, dtype):
    n, p = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    X = jnp.asarray(rng.standard_normal((n, p)), dtype)
    c = jnp.asarray(rng.standard_normal(n), dtype)
    rho = 0.37
    s_ref, ss_ref = ref.edpp_screen_ref(X, c, rho)
    mask, s, ss = ops.edpp_screen(X, c, rho, interpret=True)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ss_ref), **_tol(dtype))
    # mask consistent with scores
    np.testing.assert_array_equal(np.asarray(mask),
                                  np.asarray(s) < 1.0 - 1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_screen_matvec_kernel(shape):
    n, p = shape
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    c = jnp.asarray(rng.standard_normal(n), jnp.float32)
    dot = ops.screen_matvec(X, c, interpret=True)
    np.testing.assert_allclose(np.asarray(dot),
                               np.asarray(ref.screen_matvec_ref(X, c)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m", [2, 5, 10])
@pytest.mark.parametrize("shape", [(60, 300), (100, 1000)])
def test_group_screen_kernel(shape, m):
    n, p = shape
    if p % m:
        pytest.skip("group size must divide p")
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    c = jnp.asarray(rng.standard_normal(n), jnp.float32)
    gs = ops.group_screen_scores(X, c, m, interpret=True)
    np.testing.assert_allclose(np.asarray(gs),
                               np.asarray(ref.group_screen_ref(X, c, m)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("p", [64, 777, 4096])
@pytest.mark.parametrize("dtype", DTYPES)
def test_prox_step_kernel(p, dtype):
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.standard_normal(p), dtype)
    g = jnp.asarray(rng.standard_normal(p), dtype)
    b = jnp.asarray(rng.standard_normal(p), dtype)
    bn_ref, zn_ref = ref.prox_step_ref(z, g, b, 0.01, 2.5, 0.6)
    bn, zn = ops.prox_step(z, g, b, 0.01, 2.5, 0.6, interpret=True)
    np.testing.assert_allclose(np.asarray(bn, np.float32),
                               np.asarray(bn_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(zn, np.float32),
                               np.asarray(zn_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fista_step_kernel(shape, dtype):
    n, p = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    X = jnp.asarray(rng.standard_normal((n, p)), dtype)
    r = jnp.asarray(rng.standard_normal(n), dtype)
    z = jnp.asarray(rng.standard_normal(p), dtype)
    b = jnp.asarray(rng.standard_normal(p), dtype)
    bn_ref, zn_ref = ref.fista_step_ref(X, r, z, b, 0.01, 2.5, 0.6)
    bn, zn = ops.fista_step(X, r, z, b, 0.01, 2.5, 0.6, interpret=True)
    np.testing.assert_allclose(np.asarray(bn, np.float32),
                               np.asarray(bn_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(zn, np.float32),
                               np.asarray(zn_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b", [17, 64, 130, 512])
def test_cd_gram_sweep_kernel(b):
    rng = np.random.default_rng(b)
    A = rng.standard_normal((2 * b, b)).astype(np.float32)
    A[:, -3:] = 0.0                         # padded (zero-norm) columns
    G = jnp.asarray(A.T @ A)
    c = jnp.asarray(A.T @ rng.standard_normal(2 * b).astype(np.float32))
    beta0 = jnp.asarray(rng.standard_normal(b).astype(np.float32) * 0.1)
    lam = 0.5 * float(jnp.max(jnp.abs(c)))
    out_ref = ref.cd_gram_sweep_ref(G, c, beta0, lam, sweeps=3)
    out = ops.cd_gram_sweep(G, c, beta0, lam, sweeps=3, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-5)
    assert np.all(np.asarray(out)[-3:] == 0)   # zero-Gram cols stay fixed


def test_cd_gram_sweep_rejects_oversized():
    b = ops.GRAM_BUCKET_MAX + 1
    G = jnp.zeros((b, b), jnp.float32)
    with pytest.raises(ValueError, match="GRAM_BUCKET_MAX"):
        ops.cd_gram_sweep(G, jnp.zeros(b), jnp.zeros(b), 0.1, interpret=True)


def test_kernel_screening_matches_rule():
    """Kernel-based screening decision == reference edpp_mask decision."""
    from repro.core import DualState, edpp_mask, lambda_max, v2_perp
    rng = np.random.default_rng(4)
    n, p = 50, 400
    X = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(n), jnp.float32)
    lmax = float(lambda_max(X, y))
    lam = 0.5 * lmax
    state = DualState.at_lambda_max(X, y)
    vp = v2_perp(y, lam, state)
    centre = state.theta + 0.5 * vp
    rho = 0.5 * float(jnp.linalg.norm(vp))
    mask_k, _, _ = ops.edpp_screen(X, centre, rho, interpret=True)
    mask_ref = edpp_mask(X, y, lam, state)
    np.testing.assert_array_equal(np.asarray(mask_k), np.asarray(mask_ref))


# ---------------------------------------------------------------------------
# Batch axis: every query-side op accepts (B, ·) operands — kernels vs refs
# vs per-row single-query calls (one fitted dictionary, B queries)
# ---------------------------------------------------------------------------

BATCHES = [1, 3, 8, 17]


@pytest.mark.parametrize("batch", BATCHES)
def test_edpp_screen_kernel_batched(batch):
    n, p = 60, 300
    rng = np.random.default_rng(batch)
    X = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((batch, n)), jnp.float32)
    rho = jnp.asarray(rng.uniform(0.1, 1.0, batch), jnp.float32)
    s_ref, ss_ref = ref.edpp_screen_ref(X, C, rho)
    s, ss = ops.edpp_screen_scores(X, C, rho, interpret=True)
    assert s.shape == (batch, p) and ss.shape == (p,)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ss_ref), rtol=2e-5)
    # per-row: batched row b == single-query call on query b (to fp tol)
    for b in range(batch):
        s1, _ = ops.edpp_screen_scores(X, C[b], float(rho[b]),
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(s[b]), np.asarray(s1),
                                   rtol=2e-6, atol=2e-5)


@pytest.mark.parametrize("batch", BATCHES)
def test_screen_matvec_kernel_batched(batch):
    n, p = 45, 260
    rng = np.random.default_rng(10 + batch)
    X = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((batch, n)), jnp.float32)
    dot = ops.screen_matvec(X, C, interpret=True)
    assert dot.shape == (batch, p)
    np.testing.assert_allclose(np.asarray(dot),
                               np.asarray(ref.screen_matvec_ref(X, C)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("batch", BATCHES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fista_step_kernel_batched(batch, dtype):
    n, p = 40, 200
    rng = np.random.default_rng(20 + batch)
    X = jnp.asarray(rng.standard_normal((n, p)), dtype)
    R = jnp.asarray(rng.standard_normal((batch, n)), dtype)
    Z = jnp.asarray(rng.standard_normal((batch, p)), dtype)
    Bo = jnp.asarray(rng.standard_normal((batch, p)), dtype)
    lam = jnp.asarray(rng.uniform(0.5, 2.0, batch), jnp.float32)
    bn_ref, zn_ref = ref.fista_step_ref(X, R, Z, Bo, 0.01, lam, 0.6)
    bn, zn = ops.fista_step(X, R, Z, Bo, 0.01, lam, 0.6, interpret=True)
    assert bn.shape == (batch, p)
    np.testing.assert_allclose(np.asarray(bn, np.float32),
                               np.asarray(bn_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(zn, np.float32),
                               np.asarray(zn_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("batch", BATCHES)
def test_prox_step_kernel_batched(batch):
    p = 333
    rng = np.random.default_rng(30 + batch)
    Z = jnp.asarray(rng.standard_normal((batch, p)), jnp.float32)
    G = jnp.asarray(rng.standard_normal((batch, p)), jnp.float32)
    Bo = jnp.asarray(rng.standard_normal((batch, p)), jnp.float32)
    lam = jnp.asarray(rng.uniform(0.5, 2.0, batch), jnp.float32)
    bn_ref, zn_ref = ref.prox_step_ref(Z, G, Bo, 0.01, lam, 0.6)
    bn, zn = ops.prox_step(Z, G, Bo, 0.01, lam, 0.6, interpret=True)
    np.testing.assert_allclose(np.asarray(bn), np.asarray(bn_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(zn), np.asarray(zn_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Mixed precision: bf16 screen copy + margin-aware f32 fallback must give
# masks BIT-IDENTICAL to the f32 engine (docs/kernels.md)
# ---------------------------------------------------------------------------

BF16_RULES = ["edpp", "dpp", "imp1", "imp2", "seq_safe", "safe", "strong",
              # per-piece margin screens (ISSUE 9): two stacked dots, each
              # banded by its own linear-regime margin
              "gap", "dome",
              "dpp_cut", "imp1_cut", "imp2_cut", "edpp_cut", "seq_safe_cut",
              "gap_cut"]


def test_bf16_margin_bounds_quantisation():
    """bf16_column_err dominates the true per-column dot error for any
    full-precision centre (Cauchy-Schwarz), in scalar and batched shapes."""
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.standard_normal((40, 120)), jnp.float32)
    Xb = X.astype(jnp.bfloat16)
    err = ops.bf16_column_err(X, Xb)
    assert err.shape == (120,)
    c = jnp.asarray(rng.standard_normal(40), jnp.float32)
    true_err = jnp.abs(Xb.astype(jnp.float32).T @ c - X.T @ c)
    margin = ops.bf16_score_margin(err, jnp.linalg.norm(c))
    assert margin.shape == (120,)
    assert np.all(np.asarray(true_err) <= np.asarray(margin))
    mB = ops.bf16_score_margin(err, jnp.ones(3))
    assert mB.shape == (3, 120)


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
@pytest.mark.parametrize("rule", BF16_RULES)
def test_bf16_engine_masks_bit_identical(backend, rule):
    """Sweep: the bf16 fast path + narrow f32 fallback equals the f32
    engine mask exactly, at strictly fewer screen bytes and ≤ +1 pass."""
    from repro.core import ScreeningEngine
    rng = np.random.default_rng(7)
    n, p = 48, 320
    X = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(n), jnp.float32)
    e32 = ScreeningEngine(X, y, backend=backend)
    e16 = ScreeningEngine(X, y, backend=backend, screen_dtype="bfloat16")
    st = e32.state_at_lambda_max()
    for frac in (0.8, 0.5, 0.2):
        lam = frac * e32.lam_max
        m32 = np.asarray(e32.screen(lam, st, rule))
        m16 = np.asarray(e16.screen(lam, st, rule))
        np.testing.assert_array_equal(m16, m32, err_msg=f"{rule}@{frac}")
        assert e16.last_screen_bytes < e32.last_screen_bytes
        assert e16.last_x_passes <= e32.last_x_passes + 1


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_bf16_adversarial_band_fallback(backend):
    """Columns PLANTED with scores inside the bf16 error band of the
    decision threshold: the margin fallback must fire (a bf16-only pass
    would misclassify some of them) and the final mask must still equal
    the f32 engine's bit-for-bit."""
    from repro.core import ScreeningEngine
    rng = np.random.default_rng(17)
    n, p = 32, 256
    X = rng.standard_normal((n, p)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    yn = (y / np.linalg.norm(y)).astype(np.float64)
    lmax = float(np.abs(X.astype(np.float64).T @ y.astype(np.float64)).max())
    lam = 0.5 * lmax
    eps = 1e-6                       # scr.EPS_DEFAULT
    thresh = 1.0 - eps / lam         # engine "safe" threshold at λ scale
    # safe-sphere score of a column α·ŷ is linear in α:
    #   |αŷᵀ(y/λ)| + α‖y‖(1/λ − 1/λmax) = α·slope
    ynorm = float(np.linalg.norm(y.astype(np.float64)))
    slope = ynorm * (2.0 / lam - 1.0 / lmax)
    alpha_star = thresh / slope      # score lands exactly ON the threshold
    assert alpha_star * ynorm < 0.9 * lmax   # planting can't move λ_max
    # ladder of score offsets spanning ± the expected bf16 band
    # (≈ 2·(2⁻⁹/√3)·α‖c‖, ‖c‖ = ‖y‖/λ); δ ≈ 0 is inside ANY nonzero margin
    band = 2.0 * (2.0 ** -9) / np.sqrt(3.0) * alpha_star * ynorm / lam
    n_plant = 24
    for j, d in enumerate(np.linspace(-band, band, n_plant)):
        X[:, j] = ((alpha_star + d / slope) * yn).astype(np.float32)
    Xf, yf = jnp.asarray(X), jnp.asarray(y)
    e32 = ScreeningEngine(Xf, yf, backend=backend)
    e16 = ScreeningEngine(Xf, yf, backend=backend, screen_dtype="bfloat16")
    lam = 0.5 * e32.lam_max
    m32 = np.asarray(e32.screen(lam, None, "safe"))
    m16 = np.asarray(e16.screen(lam, None, "safe"))
    np.testing.assert_array_equal(m16, m32)
    assert e16.last_fallback_cols > 0, "planted band never triggered"
    assert e16.last_x_passes == 2      # wide bf16 pass + narrow f32 re-test
    # the ladder straddles the threshold: the mask splits inside it
    planted = m32[:n_plant]
    assert planted.any() and not planted.all()


def _dome_pieces(X, y, lam):
    """The engine's dome geometry recomputed from scratch: (c, rho, ghat,
    b_cut, istar, lam_max) — the pieces dome_scores consumes."""
    import repro.core.screening as scr
    corr = np.asarray(X, np.float64).T @ np.asarray(y, np.float64)
    istar = int(np.argmax(np.abs(corr)))
    lmax = float(np.abs(corr[istar]))
    g = np.sign(corr[istar]) * np.asarray(X[:, istar], np.float64)
    gnorm = float(np.linalg.norm(g))
    ghat = (g / gnorm).astype(np.float32)
    b_cut = np.float32(1.0 / gnorm)
    c = (np.asarray(y, np.float64) / lam).astype(np.float32)
    rho = np.float32(np.linalg.norm(y) * (1.0 / lam - 1.0 / lmax))
    return c, rho, ghat, b_cut, istar, lmax


def _plant_sup_ladder(X, cols, deltas, centre, rho, ghat, b_cut, dirs=None):
    """Rescale (or overwrite, when ``dirs`` is given) the chosen columns so
    their dome/cut sup lands at (1 − eps)·(1 + δ) — the sup is positively
    homogeneous in the column, so one oracle evaluation per column fixes
    the scale exactly (up to f32 noise ≪ the ladder spacing)."""
    import repro.core.screening as scr
    eps = 1e-6
    for j, d in zip(cols, deltas):
        xj = X[:, j] if dirs is None else dirs[j]
        xj = np.asarray(xj, np.float64)
        sup = float(scr.dome_scores(
            jnp.asarray([xj @ centre], jnp.float32),
            jnp.asarray([xj @ ghat], jnp.float32),
            jnp.asarray([np.linalg.norm(xj)], jnp.float32),
            jnp.asarray(centre), jnp.asarray(rho), jnp.asarray(ghat),
            jnp.asarray(b_cut))[0])
        X[:, j] = (xj * (1.0 - eps) * (1.0 + d) / sup).astype(np.float32)


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_bf16_adversarial_dome_boundary(backend):
    """Columns planted with dome sup on a ladder straddling the 1 − eps
    discard threshold (the dome rule's own regime boundary): the per-piece
    margin fallback must fire and the bf16 mask must equal the f32 mask
    bit-for-bit, with the ladder splitting across the threshold."""
    from repro.core import ScreeningEngine
    rng = np.random.default_rng(23)
    n, p, n_plant = 32, 256, 16
    X = rng.standard_normal((n, p)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    c, rho, ghat, b_cut, istar, lmax = _dome_pieces(X, y, 0.5 * float(
        np.max(np.abs(X.T @ y))))
    lam = 0.5 * lmax
    c, rho, ghat, b_cut, istar, lmax = _dome_pieces(X, y, lam)
    cols = [j for j in range(p - n_plant - 1, p) if j != istar][:n_plant]
    # ± the relative bf16 band (~2·2⁻⁹/√3 ≈ 2.3e-3); δ ≈ 0 rungs sit inside
    # ANY nonzero margin, the extremes outside it
    deltas = np.linspace(-2.5e-3, 2.5e-3, n_plant)
    _plant_sup_ladder(X, cols, deltas, c, rho, ghat, b_cut)
    # planting must not move the λ_max geometry the pieces came from
    corr = np.abs(X.T @ y)
    assert int(np.argmax(corr)) == istar
    assert float(np.max(corr[cols])) < 0.9 * lmax
    Xf, yf = jnp.asarray(X), jnp.asarray(y)
    e32 = ScreeningEngine(Xf, yf, backend=backend)
    e16 = ScreeningEngine(Xf, yf, backend=backend, screen_dtype="bfloat16")
    st = e32.state_at_lambda_max()
    m32 = np.asarray(e32.screen(lam, st, "dome"))
    m16 = np.asarray(e16.screen(lam, st, "dome"))
    np.testing.assert_array_equal(m16, m32)
    assert e16.last_fallback_cols > 0, "planted dome band never triggered"
    planted = m32[cols]
    assert planted.any() and not planted.all()
    assert not m32[istar], "dome discarded istar (sup there is exactly 1)"


@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_bf16_adversarial_cut_corner(backend):
    """edpp_cut columns planted AT the two-plane corner of the cut sup —
    t_star = ĝᵀx/‖x‖ ≈ t_b, where the closed form switches between the
    unclipped sphere maximiser and the spherical-cap regime — AND with sup
    on a ladder straddling the discard threshold. Both per-piece margins
    (centre dot and cut dot) are live here; masks must stay bit-identical
    with the fallback firing."""
    import repro.core.screening as scr
    from repro.core import ScreeningEngine
    rng = np.random.default_rng(29)
    n, p, n_plant = 32, 256, 16
    X = rng.standard_normal((n, p)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    corr = np.abs(X.astype(np.float64).T @ y)
    istar = int(np.argmax(corr))
    lmax = float(corr[istar])
    _, _, ghat, b_cut, _, _ = _dome_pieces(X, y, 0.5 * lmax)
    from repro.core import DualState
    st = DualState.at_lambda_max(jnp.asarray(X), jnp.asarray(y))
    lam = None
    for frac in (0.5, 0.7, 0.3, 0.9):
        test = scr.make_sphere("edpp", jnp.asarray(y), frac * lmax, st)
        centre = np.asarray(test.centre, np.float64)
        rho_s = float(test.rho)
        t_b = float(scr.dome_t_b(test.centre, test.rho, jnp.asarray(ghat),
                                 jnp.asarray(b_cut)))
        if -0.95 < t_b < 0.95:       # interior corner exists at this λ
            lam = frac * lmax
            break
    assert lam is not None, "no λ with an interior clipping corner"
    # orthonormal u ⊥ ĝ; dirs sweep t through the corner while the ladder
    # sweeps the sup through the threshold
    u = rng.standard_normal(n)
    u -= (u @ ghat) * ghat.astype(np.float64)
    u /= np.linalg.norm(u)
    cols = [j for j in range(p - n_plant - 1, p) if j != istar][:n_plant]
    t_off = np.linspace(-0.02, 0.02, n_plant)
    dirs = {j: np.clip(t_b + dt, -0.99, 0.99) * ghat.astype(np.float64)
            + np.sqrt(1.0 - np.clip(t_b + dt, -0.99, 0.99) ** 2) * u
            for j, dt in zip(cols, t_off)}
    deltas = np.linspace(-2.5e-3, 2.5e-3, n_plant)
    _plant_sup_ladder(X, cols, deltas, centre.astype(np.float32), rho_s,
                      ghat, b_cut, dirs=dirs)
    corr2 = np.abs(X.T @ y)
    assert int(np.argmax(corr2)) == istar
    assert float(np.max(corr2[cols])) < 0.9 * lmax
    Xf, yf = jnp.asarray(X), jnp.asarray(y)
    e32 = ScreeningEngine(Xf, yf, backend=backend)
    e16 = ScreeningEngine(Xf, yf, backend=backend, screen_dtype="bfloat16")
    st = e32.state_at_lambda_max()
    m32 = np.asarray(e32.screen(lam, st, "edpp_cut"))
    m16 = np.asarray(e16.screen(lam, st, "edpp_cut"))
    np.testing.assert_array_equal(m16, m32)
    assert e16.last_fallback_cols > 0, "planted corner band never triggered"
    planted = m32[cols]
    assert planted.any() and not planted.all()


@pytest.mark.parametrize("batch", [2, 9])
def test_cd_gram_sweep_kernel_batched_with_valid(batch):
    b = 48
    rng = np.random.default_rng(40 + batch)
    A = rng.standard_normal((2 * b, b)).astype(np.float32)
    A[:, -3:] = 0.0
    G = jnp.asarray(A.T @ A)
    C = jnp.asarray(rng.standard_normal((batch, b)), jnp.float32)
    beta0 = jnp.asarray(rng.standard_normal((batch, b)) * 0.1, jnp.float32)
    valid = jnp.asarray(rng.uniform(size=(batch, b)) > 0.3, jnp.float32)
    lam = jnp.asarray(rng.uniform(0.5, 2.0, batch), jnp.float32)
    out_ref = ref.cd_gram_sweep_ref(G, C, beta0 * valid, lam, sweeps=2,
                                    valid=valid)
    out = ops.cd_gram_sweep(G, C, beta0 * valid, lam, sweeps=2, valid=valid,
                            interpret=True)
    assert out.shape == (batch, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-5)
    # per-query screened-out columns are pinned at zero
    assert np.all(np.asarray(out) * (1 - np.asarray(valid)) == 0)
    assert np.all(np.asarray(out)[:, -3:] == 0)   # zero-Gram cols too
