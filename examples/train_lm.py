"""End-to-end training driver: a ~100M-parameter LM through the production
stack — sharded train_step, AdamW, deterministic data pipeline, atomic
checkpointing, elastic restart.

    PYTHONPATH=src python examples/train_lm.py --steps 30          # demo
    PYTHONPATH=src python examples/train_lm.py --steps 300 --seq 512

A few hundred steps at the full size is a multi-hour CPU run (it is a real
100M model); the default demo settings show the same code path in minutes.
On TPU the identical script runs on the production mesh (--mesh 16x16).
"""

import argparse
import os
import time

import jax
import numpy as np

from repro.configs.common import dense_lm
from repro.checkpoint import latest_step, restore, save
from repro.data import SyntheticLM, device_batch
from repro.optim import adamw
from repro.train import steps as ST


def lm_100m(seq_vocab=32000):
    """~103M params: 12L, d=640, 10 heads, d_ff=2560, tied embeddings."""
    return dense_lm("lm-100m", n_layers=12, d_model=640, n_heads=10,
                    n_kv_heads=10, d_head=64, d_ff=2560, vocab=seq_vocab)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tiny", action="store_true",
                    help="4L/d256 variant for smoke runs")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args()

    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh(dshape, ("data", "model")[: len(dshape)])

    if args.tiny:
        cfg = dense_lm("lm-tiny", n_layers=4, d_model=256, n_heads=4,
                       n_kv_heads=4, d_head=64, d_ff=1024, vocab=8000)
    else:
        cfg = lm_100m()
    tc = ST.TrainConfig(opt=adamw.OptConfig(
        lr=3e-4, warmup_steps=20, total_steps=max(args.steps, 100)))

    state, state_sh = ST.init_state(jax.random.PRNGKey(0), cfg, tc, mesh)
    nparams = sum(np.prod(x.shape, dtype=np.float64)
                  for x in jax.tree.leaves(state.params))
    print(f"model {cfg.name}: {nparams/1e6:.1f}M params, mesh {dshape}")

    src = SyntheticLM(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch)
    batch0 = device_batch(mesh, src.host_batch(0))
    bsh = {k: v.sharding for k, v in batch0.items()}
    step_fn = ST.make_train_step(cfg, tc, mesh, state_sh, bsh)

    start = 0
    last = latest_step(args.ckpt_dir)
    if last is not None:
        print(f"resuming from checkpoint step {last}")
        state, _ = restore(args.ckpt_dir, last, state, shardings=state_sh)
        start = last

    t_tokens = 0
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        batch = device_batch(mesh, src.host_batch(i))
        state, metrics = step_fn(state, batch)
        t_tokens += args.batch * args.seq
        if i % 5 == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {i:4d}  loss {float(metrics['loss']):7.4f}"
                  f"  lr {float(metrics['lr']):.2e}"
                  f"  {t_tokens/max(dt,1e-9):,.0f} tok/s")
        if (i + 1) % args.ckpt_every == 0 or i == args.steps - 1:
            save(args.ckpt_dir, i + 1, state)
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
