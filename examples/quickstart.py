"""Quickstart: safe Lasso screening with EDPP (paper's headline workflow).

Solves a 100-point λ-path on a synthetic problem (paper eq. 74) twice —
without screening and with sequential EDPP — and prints per-λ rejection
ratios and the end-to-end speedup. Runs in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import PathConfig, lambda_grid, lambda_max, lasso_path
from repro.data import lasso_problem
import jax.numpy as jnp


def main():
    n, p, nnz = 150, 3000, 60
    print(f"synthetic lasso: X is {n}x{p}, {nnz} true nonzeros (eq. 74)")
    X, y, beta_true = lasso_problem(n, p, nnz=nnz, corr=0.5, sigma=0.1)

    lmax = float(lambda_max(jnp.asarray(X), jnp.asarray(y)))
    grid = lambda_grid(lmax, num=100)

    # warm compiles out of the timing (the paper's MATLAB has none either)
    lasso_path(X, y, grid[:4], PathConfig(rule="none"))
    lasso_path(X, y, grid[:4], PathConfig(rule="edpp"))

    t0 = time.perf_counter()
    ref = lasso_path(X, y, grid, PathConfig(rule="none", solver_tol=1e-10))
    t_plain = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = lasso_path(X, y, grid, PathConfig(rule="edpp", solver_tol=1e-10))
    t_edpp = time.perf_counter() - t0

    err = np.abs(res.betas - ref.betas).max()
    print(f"\nmax |beta_screened - beta_plain| = {err:.2e}  (safe: exact)")
    print(f"unscreened path : {t_plain:6.2f}s")
    print(f"EDPP path       : {t_edpp:6.2f}s   speedup {t_plain/t_edpp:5.1f}x")
    print(f"screening cost  : {res.total_screen_time:6.3f}s\n")

    print("  λ/λmax   discarded     kept  rejection-ratio")
    for k in range(0, 100, 10):
        s = res.stats[k]
        nz = int((np.abs(ref.betas[k]) <= 1e-9).sum())
        print(f"  {s.lam/lmax:6.2f}   {s.n_discarded:9d} {s.n_kept:8d}"
              f"  {s.n_discarded/max(nz,1):10.3f}")


if __name__ == "__main__":
    main()
