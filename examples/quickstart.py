"""Quickstart: safe Lasso screening with EDPP (paper's headline workflow).

Fits ONE :class:`repro.LassoSession` on a synthetic problem (paper
eq. 74) — the fused dictionary-fit pass over X runs exactly once — then
solves the same 100-point λ-path twice through ``session.path``: without
screening and with sequential EDPP. Prints per-λ rejection ratios and the
end-to-end speedup. Runs in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py [--quick]

``--quick`` shrinks the problem for CI smoke runs (INTERPRET=1 friendly).
"""

import argparse
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro import LassoSession, PathConfig, ScreenSpec, SolveSpec
from repro.data import lasso_problem


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke runs")
    args = ap.parse_args(argv)

    n, p, nnz, K = (60, 400, 12, 12) if args.quick else (150, 3000, 60, 100)
    print(f"synthetic lasso: X is {n}x{p}, {nnz} true nonzeros (eq. 74)")
    X, y, beta_true = lasso_problem(n, p, nnz=nnz, corr=0.5, sigma=0.1)

    # ONE session: the dictionary side (‖x_j‖², column norms, Lipschitz
    # cache) is fitted once and shared by both path runs below.
    sess = LassoSession.fit(X, config=PathConfig(
        screen=ScreenSpec(rule="edpp"), solve=SolveSpec(tol=1e-10)))
    plain = PathConfig(screen=ScreenSpec(rule="none"),
                       solve=SolveSpec(tol=1e-10))

    # warm compiles out of the timing (the paper's MATLAB has none either)
    grid_kw = dict(num_lambdas=K)
    sess.path(y, num_lambdas=4, config=plain)
    sess.path(y, num_lambdas=4)

    t0 = time.perf_counter()
    ref = sess.path(y, config=plain, **grid_kw).squeeze()
    t_plain = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = sess.path(y, **grid_kw).squeeze()
    t_edpp = time.perf_counter() - t0

    assert sess.fit_passes == 1, "dictionary must be fitted exactly once"
    lmax = float(res.lambdas[0])

    err = np.abs(res.betas - ref.betas).max()
    print(f"\nmax |beta_screened - beta_plain| = {err:.2e}  (safe: exact)")
    print(f"unscreened path : {t_plain:6.2f}s")
    print(f"EDPP path       : {t_edpp:6.2f}s   speedup {t_plain/t_edpp:5.1f}x")
    print(f"screening cost  : {res.total_screen_time:6.3f}s")
    print(f"dictionary fit  : once per session "
          f"(fused passes: {sess.fit_passes}, "
          f"query attaches: {sess.query_passes})\n")

    print("  λ/λmax   discarded     kept  rejection-ratio")
    for k in range(0, K, max(K // 10, 1)):
        s = res.stats[k]
        nz = int((np.abs(ref.betas[k]) <= 1e-9).sum())
        print(f"  {s.lam/lmax:6.2f}   {s.n_discarded:9d} {s.n_kept:8d}"
              f"  {s.n_discarded/max(nz,1):10.3f}")


if __name__ == "__main__":
    main()
