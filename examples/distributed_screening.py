"""Distributed EDPP screening + FISTA on a virtual 8-chip mesh.

Demonstrates the production multi-chip layout (DESIGN §7): X column-sharded
over every mesh axis, dual geometry replicated, screening with zero
communication, solver with one N-vector psum per iteration (chunked-overlap
schedule). The identical code lowers on the 256/512-chip production meshes
in the dry-run (cells lasso-screen-16m / lasso-fista-16m).

    PYTHONPATH=src python examples/distributed_screening.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DualState, distributed as D, edpp_mask, lambda_max
from repro.data import lasso_problem


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    n, p = 256, 1 << 15
    X, y, beta_true = lasso_problem(n, p, nnz=40, sigma=0.1,
                                    dtype=np.float32)
    Xd, yd = D.shard_problem(mesh, X, y)
    print(f"X: {n}x{p} sharded column-wise → "
          f"{p // mesh.size} features/chip")

    lmax_d, matvec_d, screen_d, sup_d = D.make_dist_ops(mesh)
    lm = float(lmax_d(Xd, yd))
    print(f"λ_max = {lm:.3f}  (one scalar pmax)")

    corr = X.T @ y
    istar = int(np.argmax(np.abs(corr)))
    v1max = jnp.asarray(np.sign(corr[istar]) * X[:, istar])
    beta0 = jax.device_put(jnp.zeros(p, jnp.float32),
                           D.beta_sharding(mesh))

    # basic (λmax-state) screening is tight near λmax; the sequential rule
    # handles small λ (see quickstart.py for the full-path behaviour)
    lam = 0.8 * lm
    t0 = time.perf_counter()
    mask, scores = D.dist_edpp_screen(mesh, Xd, yd, lam, lm, beta0, lm,
                                      v1max)
    mask.block_until_ready()
    t_screen = time.perf_counter() - t0
    n_disc = int(np.asarray(mask).sum())
    print(f"EDPP at λ={lam:.2f}: discarded {n_disc}/{p} features "
          f"in {t_screen*1e3:.1f} ms (screening is comm-free)")

    # verify against the single-device reference rule
    st = DualState.at_lambda_max(jnp.asarray(X), jnp.asarray(y))
    ref = np.asarray(edpp_mask(jnp.asarray(X), jnp.asarray(y), lam, st))
    assert np.array_equal(np.asarray(mask), ref), "distributed == local"
    print("distributed mask == single-device mask ✓")

    lam = 0.3 * lm                       # solve deeper into the path
    L = D.dist_power_iteration(mesh, Xd) * 1.05
    t0 = time.perf_counter()
    beta = D.dist_fista(mesh, Xd, yd, lam, beta0, L, iters=300,
                        overlap="chunked")
    beta.block_until_ready()
    print(f"distributed FISTA (300 iters, chunked-overlap psum): "
          f"{time.perf_counter()-t0:.2f}s")
    bh = np.asarray(beta)
    print(f"recovered support: {int((np.abs(bh) > 1e-4).sum())} features "
          f"(true: {int((beta_true != 0).sum())})")


if __name__ == "__main__":
    main()
