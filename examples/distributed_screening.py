"""Distributed EDPP screening + FISTA on a virtual 8-chip mesh.

Demonstrates the production multi-chip layout (docs/distributed.md) at
two levels:

  1. **The session front door** — ``LassoSession.fit(X, mesh=mesh)`` on a
     2D ``--mesh QxF`` (axes ``('query', 'feature')``) places the
     dictionary column-sharded over the feature axis, shards query
     batches over the query axis, and resolves the screen backend to the
     per-shard tile dispatcher (``session.backend_name ==
     "shard:<tile>"``): each device runs the SAME Pallas/jnp kernels as
     the single-chip engines on its local block, and masks come out
     bit-identical to the unsharded session.
  2. **The explicit shard_map suite** (`repro.core.distributed`) — the
     hand-written collectives the session path is built from: per-shard
     tile screening with zero communication, FISTA with one N-vector
     psum per iteration (chunked-overlap schedule).

The identical code lowers on the 256/512-chip production meshes in the
dry-run (cells lasso-screen-16m / lasso-fista-16m).

    PYTHONPATH=src python examples/distributed_screening.py \
        [--quick] [--mesh 2x4]

``--quick`` shrinks shapes for CI smoke runs (INTERPRET=1 friendly).
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import LassoSession, PathConfig
from repro.core import DualState, distributed as D, edpp_mask, lambda_max
from repro.data import lasso_problem


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke runs")
    ap.add_argument("--mesh", default="2x4", metavar="QxF",
                    help="2D device mesh 'QxF': Q query shards × F "
                         "feature shards (default 2x4 on the 8 virtual "
                         "devices)")
    args = ap.parse_args(argv)

    q, f = (int(t) for t in args.mesh.lower().split("x"))
    mesh = jax.make_mesh((q, f), ("query", "feature"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    n, p = (128, 1 << 12) if args.quick else (256, 1 << 15)
    fista_iters = 60 if args.quick else 300
    X, y, beta_true = lasso_problem(n, p, nnz=40, sigma=0.1,
                                    dtype=np.float32)

    # ---- level 1: the session front door (per-shard tile kernels) ------
    # f32 serving precision: a 1e-8 relative gap is unreachable in f32 and
    # would burn max_iter per step — demo at the f32-appropriate tolerance
    sess = LassoSession.fit(X, mesh=mesh,
                            config=PathConfig(solver_tol=2e-5, max_iter=600))
    print(f"X: {n}x{p} sharded column-wise → "
          f"{p // f} features/shard; screen backend "
          f"{sess.backend_name} (session fused fit passes: "
          f"{sess.fit_passes})")
    t0 = time.perf_counter()
    res = sess.path(y, num_lambdas=5, lo_frac=0.3)
    t_path = time.perf_counter() - t0
    for s in res.stats:
        print(f"  session path λ={s.lam:7.2f}: discarded {s.n_discarded:6d}"
              f"/{p} kept {s.n_kept:5d} iters {s.solver_iters}")
    print(f"session 5-point path on the mesh: {t_path:.2f}s "
          f"(per-shard tile screens, replicated reduced solves)")

    # the batched front door shards queries over the mesh's query axis
    Yb = np.stack([y] * (2 * q)).astype(np.float32)
    res_b = sess.path(Yb, num_lambdas=3, lo_frac=0.3)
    print(f"batched path B={Yb.shape[0]} (query-sharded over {q} shard"
          f"{'s' if q > 1 else ''}): masks {res_b.masks.shape}")

    # ---- level 2: the explicit shard_map collectives ------------------
    Xd, yd = D.shard_problem(mesh, X, y)
    lmax_d, matvec_d, screen_d, sup_d = D.make_dist_ops(mesh)
    lm = float(lmax_d(Xd, yd))
    print(f"λ_max = {lm:.3f}  (one scalar pmax)")

    corr = X.T @ y
    istar = int(np.argmax(np.abs(corr)))
    v1max = jnp.asarray(np.sign(corr[istar]) * X[:, istar])
    beta0 = jax.device_put(jnp.zeros(p, jnp.float32),
                           D.beta_sharding(mesh))

    # basic (λmax-state) screening is tight near λmax; the sequential rule
    # handles small λ (see quickstart.py for the full-path behaviour)
    lam = 0.8 * lm
    t0 = time.perf_counter()
    mask, scores = D.dist_edpp_screen(mesh, Xd, yd, lam, lm, beta0, lm,
                                      v1max)
    mask.block_until_ready()
    t_screen = time.perf_counter() - t0
    n_disc = int(np.asarray(mask).sum())
    print(f"EDPP at λ={lam:.2f}: discarded {n_disc}/{p} features "
          f"in {t_screen*1e3:.1f} ms (screening is comm-free)")

    # verify against the single-device reference rule
    st = DualState.at_lambda_max(jnp.asarray(X), jnp.asarray(y))
    ref = np.asarray(edpp_mask(jnp.asarray(X), jnp.asarray(y), lam, st))
    assert np.array_equal(np.asarray(mask), ref), "distributed == local"
    print("distributed mask == single-device mask ✓")

    lam = 0.3 * lm                       # solve deeper into the path
    L = D.dist_power_iteration(mesh, Xd) * 1.05
    t0 = time.perf_counter()
    beta = D.dist_fista(mesh, Xd, yd, lam, beta0, L, iters=fista_iters,
                        overlap="chunked")
    beta.block_until_ready()
    print(f"distributed FISTA ({fista_iters} iters, chunked-overlap psum): "
          f"{time.perf_counter()-t0:.2f}s")
    bh = np.asarray(beta)
    print(f"recovered support: {int((np.abs(bh) > 1e-4).sum())} features "
          f"(true: {int((beta_true != 0).sum())})")


if __name__ == "__main__":
    main()
