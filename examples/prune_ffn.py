"""Group-EDPP structured pruning of a trained LM's FFN neurons — the
framework bridge between the paper's technique and the architecture zoo
(DESIGN §5.1).

Recipe:
  1. train a tiny LM for a few steps (production train_step);
  2. collect FFN hidden activations H ∈ R^{tokens × d_ff} of one layer and
     the layer's output contribution t = H·W_out (per output dim, we fit the
     pooled target);
  3. group Lasso over neuron groups (each neuron's activation column),
     solved along a λ path with group-EDPP screening (Cor. 21) — safely
     discarding neurons whose optimal weight is exactly zero;
  4. report the neuron-sparsity/reconstruction trade-off curve.

    PYTHONPATH=src python examples/prune_ffn.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import dense_lm
from repro.core import (GroupPathConfig, group_lambda_max, group_lasso_path,
                        lambda_grid)
from repro.data import SyntheticLM, device_batch
from repro.models import model as M
from repro.models.layers import ffn_forward, rmsnorm
from repro.optim import adamw
from repro.train import steps as ST


def main():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = dense_lm("prunable", n_layers=2, d_model=128, n_heads=4,
                   n_kv_heads=4, d_head=32, d_ff=256, vocab=4000)
    tc = ST.TrainConfig(opt=adamw.OptConfig(lr=3e-3, warmup_steps=5,
                                            total_steps=60))
    state, state_sh = ST.init_state(jax.random.PRNGKey(0), cfg, tc, mesh)
    src = SyntheticLM(vocab=cfg.vocab, seq=64, global_batch=4)
    b0 = device_batch(mesh, src.host_batch(0))
    bsh = {k: v.sharding for k, v in b0.items()}
    step = ST.make_train_step(cfg, tc, mesh, state_sh, bsh)
    for i in range(30):
        state, metrics = step(state, device_batch(mesh, src.host_batch(i)))
    print(f"trained tiny LM to loss {float(metrics['loss']):.3f}")

    # --- extract layer-0 FFN hidden activations on a probe batch ---------
    params = state.params
    batch = src.host_batch(99)
    x = jnp.take(params["embed"], jnp.asarray(batch["tokens"]), axis=0)
    lp = jax.tree.map(lambda a: a[0], params["segments"][0])["b0"]
    blk = cfg.segments[0].blocks[0]
    from repro.models.model import _block_forward
    # hidden pre-activations of the FFN: recompute the block's FFN input
    h2 = rmsnorm(lp["norm2"], x)
    w_in, w_gate = lp["ffn"]["w_in"], lp["ffn"]["w_gate"]
    hidden = jax.nn.silu(h2 @ w_gate) * (h2 @ w_in)       # (B,S,d_ff)
    target = hidden @ lp["ffn"]["w_out"]                  # (B,S,d)

    tokens = hidden.reshape(-1, cfg.segments[0].blocks[0].ffn.d_ff)
    tgt = np.asarray(target.reshape(-1, cfg.d_model))
    # pool the multi-output regression to a single response (first PC proxy)
    y = tgt @ (tgt.std(0) / np.linalg.norm(tgt.std(0)))
    H = np.asarray(tokens, np.float64)
    y = np.asarray(y, np.float64)

    m = 1                                    # group = one neuron column
    lmax = float(group_lambda_max(jnp.asarray(H), jnp.asarray(y), m))
    grid = lambda_grid(lmax, num=20, lo_frac=0.02)
    res = group_lasso_path(H, y, m, grid,
                           GroupPathConfig(rule="edpp", solver_tol=1e-10))

    print("\n  λ/λmax   neurons kept   screened-out   recon-R²")
    for k in [2, 6, 10, 14, 19]:
        beta = res.betas[k]
        kept = int((np.abs(beta) > 1e-9).sum())
        pred = H @ beta
        r2 = 1 - ((y - pred) ** 2).sum() / ((y - y.mean()) ** 2).sum()
        print(f"  {grid[k]/lmax:6.2f}   {kept:12d}   "
              f"{res.stats[k].n_discarded:11d}   {r2:8.3f}")
    print("\ngroup-EDPP screened the inactive neurons SAFELY — kept set is "
          "exactly the group-lasso support at each λ.")


if __name__ == "__main__":
    main()
